"""Pluggable admission policies + lazy page reservation.

Admission order is a scheduling lever, not a semantic one: every policy
(``fifo`` / ``prefix-affinity`` / ``reach-packing``) and the lazy
page-reservation path (including forced preemption on pool exhaustion)
must leave each request's token stream TOKEN-FOR-TOKEN identical to the
eager FIFO engine — per-uid seeded sampling makes streams independent
of admission order, prefill batching, and preempt/readmit round-trips.
On top of parity this file pins the policy-layer contracts: FIFO stops
at the first non-fit, prefix-affinity admits one prefill per shared
system prompt across waves (``prefill_calls_saved``), reach-packing's
bypass is bounded (``max_bypass`` rounds, then a barrier), and
preemption under a deliberately tiny pool round-trips through
park/resurrect/rebuild without corrupting a single stream.
"""

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T
from repro.serving import (Engine, FifoPolicy, PrefixAffinityPolicy,
                           ReachPackingPolicy, Request, SamplingParams,
                           get_policy)

KEY = jax.random.PRNGKey(0)

SAMPLED = SamplingParams(temperature=0.9, top_k=32, top_p=0.9, seed=11)

_MODEL = None


def _model():
    """Latent (recalkv) smoke model, cached — every test reuses it."""
    global _MODEL
    if _MODEL is None:
        cfg = get_config("qwen3-4b", smoke=True, recalkv_ratio=0.5)
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)
        _MODEL = (cfg, T.init_params(cfg, KEY))
    return _MODEL


def _prompts(cfg, n=6, seed=3, base=5):
    g = np.random.default_rng(seed)
    return [g.integers(0, cfg.vocab_size, base + 2 * i).astype(np.int32)
            for i in range(n)]


def _serve(cfg, params, prompts, *, sampling=None, max_new=6, mesh=None,
           **kw):
    eng = Engine(cfg, params, max_slots=4, max_len=40, sampling=sampling,
                 mesh=mesh, **kw)
    for i, pr in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=max_new))
    done = eng.run()
    eng.close()
    return {r.uid: r.out_tokens for r in done}, eng


def _req(uid, n=8, seed=None):
    g = np.random.default_rng(uid if seed is None else seed)
    return Request(uid=uid, prompt=g.integers(0, 99, n).astype(np.int32),
                   max_new_tokens=4)


# -- policy unit tests: selection order, no engine ---------------------------

class TestPolicySelection:

    def test_get_policy_resolves_names_and_instances(self):
        assert get_policy(None).name == "fifo"
        assert get_policy("prefix-affinity").groups_by_prefix
        assert not get_policy("reach-packing").groups_by_prefix
        inst = ReachPackingPolicy(max_bypass=1)
        assert get_policy(inst) is inst
        with pytest.raises(ValueError, match="unknown admission policy"):
            get_policy("round-robin")

    def test_fifo_first_nonfit_ends_wave(self):
        """Strict head-of-line: a blocked head starves nobody behind it
        out of ORDER — the wave just ends."""
        q = deque(_req(i) for i in range(4))
        got = FifoPolicy().select(q, 4, fits=lambda r: r.uid != 2)
        assert [r.uid for r in got] == [0, 1]
        assert [r.uid for r in q] == [2, 3]       # untouched, in order

    def test_fifo_respects_limit(self):
        q = deque(_req(i) for i in range(5))
        got = FifoPolicy().select(q, 3, fits=None)
        assert [r.uid for r in got] == [0, 1, 2]

    def test_prefix_affinity_pulls_sharers_forward(self):
        """Sharers of an already-selected first page join its wave;
        non-sharers keep FIFO order among themselves."""
        pol = PrefixAffinityPolicy()
        pol.configure(page_size=4)
        sys_p = np.arange(4, dtype=np.int32)
        mk = lambda uid, pr: Request(uid=uid, prompt=pr, max_new_tokens=4)
        a1 = mk(0, np.concatenate([sys_p, [7]]).astype(np.int32))
        b = mk(1, (sys_p + 50).astype(np.int32))
        a2 = mk(2, np.concatenate([sys_p, [9]]).astype(np.int32))
        q = deque([a1, b, a2])
        got = pol.select(q, 3)
        assert [r.uid for r in got] == [0, 2, 1]

    def test_prefix_affinity_head_never_bypassed(self):
        """With no sharer pending, selection IS FIFO — and a non-fitting
        pick ends the wave exactly like fifo."""
        pol = PrefixAffinityPolicy()
        pol.configure(page_size=4)
        q = deque(_req(i, n=8, seed=100 + i) for i in range(4))
        got = pol.select(q, 4, fits=lambda r: r.uid < 2)
        assert [r.uid for r in got] == [0, 1]
        assert [r.uid for r in q] == [2, 3]

    def test_reach_packing_admits_past_blocked_head(self):
        pol = ReachPackingPolicy(max_bypass=4)
        q = deque([_req(0, n=30), _req(1, n=4), _req(2, n=4)])
        got = pol.select(q, 4, fits=lambda r: len(r.prompt) < 10)
        assert [r.uid for r in got] == [1, 2]
        assert [r.uid for r in q] == [0]           # blocked head stays

    def test_reach_packing_barrier_after_max_bypass(self):
        """A request passed over ``max_bypass`` times becomes a hard
        barrier: nothing behind it admits until it does."""
        pol = ReachPackingPolicy(max_bypass=2)
        big = _req(0, n=30)
        fits = lambda r: len(r.prompt) < 10
        for round_ in range(2):                    # bypassed twice
            q = deque([big, _req(10 + round_, n=4)])
            assert [r.uid for r in pol.select(q, 4, fits)] == [10 + round_]
        q = deque([big, _req(20, n=4)])
        assert pol.select(q, 4, fits) == []        # barrier holds
        assert [r.uid for r in q] == [0, 20]
        # once the barrier admits, its bypass count resets
        got = pol.select(q, 4, fits=lambda r: True)
        assert [r.uid for r in got] == [0, 20]
        assert pol._bypassed == {}

    def test_reach_packing_empty_waves_dont_count(self):
        """Rounds that admitted nobody never charge the bound — an empty
        wave starves nobody."""
        pol = ReachPackingPolicy(max_bypass=1)
        big = _req(0, n=30)
        fits = lambda r: False
        for _ in range(5):
            q = deque([big])
            assert pol.select(q, 4, fits) == []
        assert pol._bypassed == {}

    def test_pick_victim_is_youngest_admission(self):
        cands = [(3, _req(0)), (1, _req(1)), (5, _req(2))]
        assert FifoPolicy().pick_victim(cands) == 5


# -- engine validation + metrics surface -------------------------------------

class TestPolicyConfigSurface:

    def test_prefix_affinity_requires_paged(self):
        cfg, params = _model()
        with pytest.raises(ValueError, match="paged"):
            Engine(cfg, params, max_slots=2, max_len=40,
                   policy="prefix-affinity")

    def test_lazy_pages_requires_paged(self):
        cfg, params = _model()
        with pytest.raises(ValueError, match="paged"):
            Engine(cfg, params, max_slots=2, max_len=40, lazy_pages=True)

    def test_lazy_pages_rejects_continuous(self):
        cfg, params = _model()
        with pytest.raises(ValueError, match="continuous"):
            Engine(cfg, params, max_slots=2, max_len=40,
                   cache_layout="paged", page_size=8, n_pages=17,
                   lazy_pages=True, overlap=True, continuous=True)

    def test_metrics_report_policy_layer(self):
        cfg, params = _model()
        got, eng = _serve(cfg, params, _prompts(cfg, n=2),
                          cache_layout="paged", page_size=8, n_pages=33,
                          policy="reach-packing", staging_depth=7)
        m = eng.metrics()
        assert m["policy"] == "reach-packing"
        assert m["staging_depth"] == 7
        assert m["lazy_pages"] is False
        assert m["preemptions"] == 0
        assert m["prefill_calls"] > 0
        assert m["prefill_calls_saved"] == 0
        # pages_free / pages_parked partition residency with pages_used
        assert m["pages_parked"] >= 0

    def test_staging_depth_defaults_to_twice_slots(self):
        cfg, params = _model()
        eng = Engine(cfg, params, max_slots=4, max_len=40)
        try:
            assert eng.metrics()["staging_depth"] == 8
            assert eng.metrics()["policy"] == "fifo"
        finally:
            eng.close()


# -- stream parity: every policy is stream-invariant -------------------------

_REF = {}


def _ref_streams(sampling=None):
    """Ring-layout eager-FIFO streams — the one reference every policy
    and layout must reproduce bit-for-bit."""
    key = "sampled" if sampling else "greedy"
    if key not in _REF:
        cfg, params = _model()
        _REF[key], _ = _serve(cfg, params, _prompts(cfg),
                              sampling=sampling)
    return _REF[key]


class TestPolicyStreamParity:

    @pytest.mark.parametrize("policy", ["fifo", "prefix-affinity",
                                        "reach-packing"])
    @pytest.mark.parametrize("overlap", [False, True])
    def test_paged_policy_matches_ring_fifo(self, policy, overlap):
        cfg, params = _model()
        got, eng = _serve(cfg, params, _prompts(cfg), cache_layout="paged",
                          page_size=8, n_pages=33, policy=policy,
                          overlap=overlap)
        assert eng.metrics()["policy"] == policy
        assert got == _ref_streams(), (policy, overlap)

    def test_explicit_fifo_continuous_matches(self):
        """policy="fifo" through the continuous-batching in-scan swap
        path is the hardcoded admission loop, bit-identical."""
        cfg, params = _model()
        got, _ = _serve(cfg, params, _prompts(cfg), cache_layout="paged",
                        page_size=8, n_pages=33, policy="fifo",
                        overlap=True, continuous=True)
        assert got == _ref_streams()

    @pytest.mark.parametrize("policy", ["prefix-affinity", "reach-packing"])
    def test_sampled_streams_match(self, policy):
        cfg, params = _model()
        got, _ = _serve(cfg, params, _prompts(cfg), sampling=SAMPLED,
                        cache_layout="paged", page_size=8, n_pages=33,
                        policy=policy)
        assert got == _ref_streams(SAMPLED), policy

    def test_policy_on_mesh_matches_single_device(self):
        """(2, 4) mesh (mesh CI job; skips below 8 devices): reordered
        admission + sharded paged pool still bit-match the reference."""
        mesh = make_test_mesh(2, 4, skip=True)
        cfg, params = _model()
        got, eng = _serve(cfg, params, _prompts(cfg), mesh=mesh,
                          cache_layout="paged", page_size=8, n_pages=33,
                          policy="prefix-affinity", overlap=True)
        assert eng.mesh_str == "2x4"
        assert got == _ref_streams()


# -- prefix-affinity: one prefill per shared system prompt -------------------

class TestPrefixAffinitySharing:

    def _shared_load(self, cfg, n=8, sys_len=16, seed=5):
        g = np.random.default_rng(seed)
        sys_p = g.integers(0, cfg.vocab_size, sys_len).astype(np.int32)
        return [np.concatenate(
            [sys_p, g.integers(0, cfg.vocab_size, 3).astype(np.int32)])
            for _ in range(n)]

    def test_shared_sysprompt_prefills_once_across_waves(self):
        """8 sharers through 4 slots = two admission waves.  FIFO
        prefills the system prompt in both; affinity's second wave rides
        the registry-resident pages (``prefill_calls_saved``) — with
        streams identical to FIFO's."""
        cfg, params = _model()
        share = self._shared_load(cfg)
        kw = dict(cache_layout="paged", page_size=4, n_pages=65)
        aff, ea = _serve(cfg, params, share, policy="prefix-affinity", **kw)
        fifo, ef = _serve(cfg, params, share, **kw)
        ma, mf = ea.metrics(), ef.metrics()
        assert aff == fifo
        assert ma["prefill_calls"] < mf["prefill_calls"], (ma, mf)
        assert ma["prefill_calls_saved"] >= 1
        assert mf["prefill_calls_saved"] == 0

    def test_intra_wave_sharing_still_cow(self):
        """Sharers landing in ONE wave share via the existing COW path:
        a single prefill call, no cross-wave skips to count."""
        cfg, params = _model()
        share = self._shared_load(cfg, n=4, sys_len=24, seed=7)
        got, eng = _serve(cfg, params, share, cache_layout="paged",
                          page_size=8, n_pages=33, policy="prefix-affinity")
        m = eng.metrics()
        assert m["prefill_calls"] == 1
        assert all(len(v) == 6 for v in got.values())


# -- lazy reservation + preemption round-trip --------------------------------

class TestLazyPreemption:
    """page_size=4, n_pages=13 against reaches of ~23-37 tokens forces
    the pool dry mid-decode: the policy picks a victim, the engine parks
    it (prefix pages pinned in the registry), and re-admission
    resurrects surviving pages / rebuilds lost ones from fed history.
    None of that may change a token vs the ample-pool engine."""

    AMPLE = dict(cache_layout="paged", page_size=4, n_pages=65)
    TINY = dict(cache_layout="paged", page_size=4, n_pages=13,
                lazy_pages=True)

    def _run(self, sampling=None, **kw):
        cfg, params = _model()
        return _serve(cfg, params, _prompts(cfg, seed=5, base=7),
                      sampling=sampling, max_new=16, sync_every=2, **kw)

    def test_lazy_ample_pool_never_preempts(self):
        ref, _ = self._run(**self.AMPLE)
        got, eng = self._run(**dict(self.AMPLE, lazy_pages=True))
        m = eng.metrics()
        assert got == ref
        assert m["preemptions"] == 0
        assert m["lazy_pages"] is True

    def test_preemption_round_trip_sync(self):
        ref, _ = self._run(**self.AMPLE)
        got, eng = self._run(**self.TINY)
        m = eng.metrics()
        assert m["preemptions"] > 0, "pool not tight enough to preempt"
        assert got == ref, "preemption corrupted a stream"

    def test_preemption_round_trip_overlap(self):
        ref, _ = self._run(**self.AMPLE)
        got, eng = self._run(overlap=True, **self.TINY)
        assert eng.metrics()["preemptions"] > 0
        assert got == ref

    def test_preemption_round_trip_sampled(self):
        """Per-uid seeded key chains make sampled streams invariant to
        the park/resurrect round-trip too."""
        ref, _ = self._run(sampling=SAMPLED, **self.AMPLE)
        got, eng = self._run(sampling=SAMPLED, **self.TINY)
        assert eng.metrics()["preemptions"] > 0
        assert got == ref

    def test_pool_stays_consistent_under_preemption(self):
        _, eng = self._run(**self.TINY)
        pool = eng._pages
        pool.assert_consistent()
        m = eng.metrics()
        # parked pages are resident (counted used), never on the free
        # list: used + free partitions the allocatable pool
        assert pool.used + m["pages_free"] == m["pages_total"] - 1
        assert m["pages_parked"] <= pool.used
