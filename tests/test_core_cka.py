import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cka


@pytest.fixture
def rng():
    return np.random.default_rng(1)


class TestLinearCKA:
    def test_self_similarity_is_one(self, rng):
        X = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
        assert float(cka.linear_cka(X, X)) == pytest.approx(1.0, abs=1e-5)

    def test_range_and_symmetry(self, rng):
        X = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
        Y = jnp.asarray(rng.normal(size=(64, 12)), jnp.float32)
        v = float(cka.linear_cka(X, Y))
        assert 0.0 <= v <= 1.0
        assert v == pytest.approx(float(cka.linear_cka(Y, X)), abs=1e-6)

    def test_orthogonal_invariance(self, rng):
        """CKA is invariant to rotations of either representation."""
        X = jnp.asarray(rng.normal(size=(48, 6)), jnp.float32)
        Y = jnp.asarray(rng.normal(size=(48, 6)), jnp.float32)
        Q, _ = np.linalg.qr(rng.normal(size=(6, 6)))
        v1 = float(cka.linear_cka(X, Y))
        v2 = float(cka.linear_cka(X @ jnp.asarray(Q, jnp.float32), Y))
        assert v1 == pytest.approx(v2, abs=1e-4)

    def test_correlated_beats_independent(self, rng):
        X = jnp.asarray(rng.normal(size=(128, 8)), jnp.float32)
        Y_corr = X @ jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
        Y_ind = jnp.asarray(rng.normal(size=(128, 8)), jnp.float32)
        assert float(cka.linear_cka(X, Y_corr)) > float(cka.linear_cka(X, Y_ind))


class TestHeadCKA:
    def test_matrix_properties(self, rng):
        reps = jnp.asarray(rng.normal(size=(6, 100, 8)), jnp.float32)
        S = np.asarray(cka.head_cka_matrix(reps))
        assert S.shape == (6, 6)
        np.testing.assert_allclose(S, S.T, atol=1e-5)
        np.testing.assert_allclose(np.diag(S), 1.0, atol=1e-4)
        assert (S >= -1e-5).all() and (S <= 1 + 1e-5).all()

    def test_cov_form_matches_feature_form(self, rng):
        """head_cka_from_cov(W, Xc^T Xc) == head_cka_matrix(Xc @ W_h)."""
        m, H, dh, N = 16, 4, 6, 200
        W = jnp.asarray(rng.normal(size=(m, H * dh)), jnp.float32)
        X = jnp.asarray(rng.normal(size=(N, m)) + 0.5, jnp.float32)
        Xc = X - X.mean(axis=0, keepdims=True)
        feats = jnp.stack([
            Xc @ W[:, h * dh:(h + 1) * dh] for h in range(H)])
        S_feat = np.asarray(cka.head_cka_matrix(feats))
        S_cov = np.asarray(cka.head_cka_from_cov(W, Xc.T @ Xc, H))
        np.testing.assert_allclose(S_cov, S_feat, rtol=1e-3, atol=1e-4)

    def test_duplicate_heads_max_similarity(self, rng):
        m, dh = 12, 4
        Wh = rng.normal(size=(m, dh))
        W = jnp.asarray(np.concatenate([Wh, Wh, rng.normal(size=(m, dh))],
                                       axis=1), jnp.float32)
        X = jnp.asarray(rng.normal(size=(300, m)), jnp.float32)
        Xc = X - X.mean(0, keepdims=True)
        S = np.asarray(cka.head_cka_from_cov(W, Xc.T @ Xc, 3))
        assert S[0, 1] == pytest.approx(1.0, abs=1e-4)
        assert S[0, 2] < 0.99
