"""Backend equivalence: the pallas kernel path (interpret mode on CPU)
must produce the same logits as the einsum reference path through full
``prefill`` + multi-step ``decode_step`` — dense, latent (ReCalKV),
int8 quantized-latent, and sliding-window configs, including ring/sequence
lengths not divisible by the kernel tile size."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.models import transformer as T
from repro.serving import Engine, Request

KEY = jax.random.PRNGKey(0)


def _cfg(arch="qwen3-4b", backend="einsum", **extra):
    kw = {k: extra.pop(k) for k in ("recalkv_ratio",) if k in extra}
    cfg = get_config(arch, smoke=True, **kw)
    return dataclasses.replace(cfg, dtype=jnp.float32, attn_backend=backend,
                               **extra)


def _run(cfg, toks, lens, max_len, steps):
    params = T.init_params(cfg, KEY)
    logits, caches = T.prefill(cfg, params, toks, lens, max_len)
    outs = [logits]
    cur = lens.astype(jnp.int32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(steps):
        logits, caches = T.decode_step(cfg, params, caches, tok, cur)
        outs.append(logits)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        cur = cur + 1
    return outs


CASES = {
    # name: (arch, extra config fields)
    "dense_qknorm": ("qwen3-4b", {}),
    "latent": ("qwen3-4b", {"recalkv_ratio": 0.5}),
    "quant_latent": ("qwen3-4b", {"recalkv_ratio": 0.5,
                                  "cache_quant_bits": 8}),
    "sliding_window": ("h2o-danube-1.8b", {}),
}


class TestBackendEquivalence:
    @pytest.mark.parametrize("case", sorted(CASES))
    def test_prefill_decode_logits_match(self, case):
        arch, extra = CASES[case]
        rng = np.random.default_rng(hash(case) % 2**31)
        B, P, max_len = 2, 9, 37          # 37 % anything-pow2 != 0
        vocab = get_config(arch, smoke=True).vocab_size
        toks = jnp.asarray(rng.integers(0, vocab, (B, P)), jnp.int32)
        lens = jnp.asarray([P, P - 3], jnp.int32)
        ref = _run(_cfg(arch, "einsum", **extra), toks, lens, max_len, steps=4)
        ker = _run(_cfg(arch, "pallas", **extra), toks, lens, max_len, steps=4)
        for i, (a, b) in enumerate(zip(ref, ker)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=2e-4, atol=2e-4,
                                       err_msg=f"{case} step {i}")

    @pytest.mark.slow
    def test_tail_tiles_beyond_one_block(self):
        """Prefill T and ring length both above (and not divisible by) the
        256 kernel tile: the padded tail must stay masked."""
        rng = np.random.default_rng(7)
        cfg_e = _cfg("qwen3-4b", "einsum", recalkv_ratio=0.5)
        toks = jnp.asarray(rng.integers(0, cfg_e.vocab_size, (2, 280)),
                           jnp.int32)
        lens = jnp.asarray([280, 133], jnp.int32)
        ref = _run(cfg_e, toks, lens, max_len=300, steps=2)
        ker = _run(_cfg("qwen3-4b", "pallas", recalkv_ratio=0.5),
                   toks, lens, max_len=300, steps=2)
        for i, (a, b) in enumerate(zip(ref, ker)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=5e-4, atol=5e-4,
                                       err_msg=f"step {i}")

    def test_engine_end_to_end_tokens_match(self):
        cfg = _cfg("qwen3-4b", recalkv_ratio=0.5)
        params = T.init_params(cfg, KEY)
        g = np.random.default_rng(3)
        prompts = [g.integers(0, cfg.vocab_size, 5 + i).astype(np.int32)
                   for i in range(4)]

        def serve(backend):
            eng = Engine(cfg, params, max_slots=2, max_len=37,
                         backend=backend)
            for i, pr in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=5))
            done = eng.run()
            return {r.uid: r.out_tokens for r in done}

        assert serve("einsum") == serve("pallas")


class TestFusedLoopParity:
    """The refactored executor (fused sync_every-token lax.scan window)
    must emit exactly the token streams of the seed engine's loop — full
    wave prefill, then one blocking host argmax per decoded token."""

    @staticmethod
    def _seed_loop(cfg, params, prompt, max_new, max_len):
        toks = jnp.asarray(prompt[None, :])
        lens = jnp.asarray([len(prompt)], jnp.int32)
        logits, caches = T.prefill(cfg, params, toks, lens, max_len)
        out = [int(np.asarray(jnp.argmax(logits, -1))[0])]
        cur = lens.astype(jnp.int32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        while len(out) < max_new and int(cur[0]) < max_len - 1:
            logits, caches = T.decode_step(cfg, params, caches, tok, cur)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(int(np.asarray(tok)[0]))
            cur = cur + 1
        return out

    @pytest.mark.parametrize("case", sorted(CASES))
    def test_sync_every_8_matches_seed_engine(self, case):
        arch, extra = CASES[case]
        cfg = _cfg(arch, "einsum", **extra)
        params = T.init_params(cfg, KEY)
        g = np.random.default_rng(hash(case) % 2**31)
        prompts = [g.integers(0, cfg.vocab_size, 4 + 2 * i).astype(np.int32)
                   for i in range(4)]
        eng = Engine(cfg, params, max_slots=4, max_len=37, sync_every=8)
        for i, pr in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=6))
        got = {r.uid: r.out_tokens for r in eng.run()}
        for i, pr in enumerate(prompts):
            ref = self._seed_loop(cfg, params, pr, 6, 37)
            assert got[i] == ref, f"{case} uid={i}"

    def test_decode_loop_device_carry_matches_stepwise(self):
        """transformer.decode_loop (token fed from device carry) must
        reproduce the per-step host argmax loop bit-for-bit."""
        cfg = _cfg("qwen3-4b", recalkv_ratio=0.5)
        params = T.init_params(cfg, KEY)
        rng = np.random.default_rng(17)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 9)), jnp.int32)
        lens = jnp.asarray([9, 6], jnp.int32)
        logits, caches = T.prefill(cfg, params, toks, lens, max_len=37)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        cur = lens.astype(jnp.int32)
        _, _, _, fused = T.decode_loop(cfg, params, caches, tok, cur, 5)
        ref = []
        c, t, u = caches, tok, cur
        for _ in range(5):
            lg, c = T.decode_step(cfg, params, c, t, u)
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            ref.append(np.asarray(t))
            u = u + 1
        np.testing.assert_array_equal(np.asarray(fused),
                                      np.stack(ref, axis=1))


class TestTrainingStaysDifferentiable:
    def test_grad_through_pallas_config(self):
        """attn_backend="pallas" must not break jax.grad: the training
        forward keeps the einsum path (kernels have no autodiff rule)."""
        cfg = dataclasses.replace(_cfg("qwen3-4b", "pallas"), remat=False)
        params = T.init_params(cfg, KEY)
        toks = jnp.zeros((2, 8), jnp.int32)
        labels = jnp.ones((2, 8), jnp.int32)

        def loss(p):
            return T.loss_fn(cfg, p, {"tokens": toks, "labels": labels})[0]

        g = jax.grad(loss)(params)
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))


class TestMixedLengthWave:
    @pytest.mark.parametrize("backend", ["einsum", "pallas"])
    def test_short_prompt_survives_long_wavemate(self, backend):
        """A short prompt admitted alongside one longer than its ring
        (sliding window 16 < padded wave T) must decode exactly as solo —
        the old bulk prefill write kept only the wave's last L columns for
        every row, erasing the short row's prefix entirely."""
        cfg = _cfg("h2o-danube-1.8b", backend)
        params = T.init_params(cfg, KEY)
        g = np.random.default_rng(31)
        short = g.integers(0, cfg.vocab_size, 8).astype(np.int32)
        long_ = g.integers(0, cfg.vocab_size, 30).astype(np.int32)

        eng = Engine(cfg, params, max_slots=2, max_len=48, backend=backend)
        eng.submit(Request(uid=0, prompt=short.copy(), max_new_tokens=5))
        eng.submit(Request(uid=1, prompt=long_.copy(), max_new_tokens=5))
        done = {r.uid: r.out_tokens for r in eng.run()}

        for uid, prompt in ((0, short), (1, long_)):
            solo = Engine(cfg, params, max_slots=1, max_len=48,
                          backend=backend)
            solo.submit(Request(uid=uid, prompt=prompt.copy(),
                                max_new_tokens=5))
            assert done[uid] == solo.run()[0].out_tokens, f"uid={uid}"


class TestInterpretResolution:
    def test_default_interpret_matches_platform(self):
        assert ops.default_interpret() == (jax.default_backend() != "tpu")

    def test_latent_decode_interpret_default(self):
        """interpret=None resolves from the platform (no kwarg needed)."""
        rng = np.random.default_rng(0)
        B, S, G, rk, rv, s, qpk, dh = 1, 40, 1, 8, 8, 2, 2, 8
        cache = {
            "zk": jnp.asarray(rng.normal(size=(B, S, G, rk)), jnp.float32),
            "zv": jnp.asarray(rng.normal(size=(B, S, G, rv)), jnp.float32),
            "pos": jnp.broadcast_to(jnp.arange(S), (B, S)),
        }
        q = jnp.asarray(rng.normal(size=(B, s * qpk * G, dh)), jnp.float32)
        r_k = jnp.asarray(rng.normal(size=(G, rk, s * dh)), jnp.float32)
        cur = jnp.asarray([S - 1])
        o = ops.latent_decode(q, cache, r_k, cur, theta=1e4, window=None,
                              scale=dh ** -0.5, block_s=16)
        o_ref = ops.latent_decode(q, cache, r_k, cur, theta=1e4, window=None,
                                  scale=dh ** -0.5, use_kernel=False)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)


def _slot_rows(cache, i):
    """Slot i's rows of every cache leaf (batch is dim 1 under blocks)."""
    def one(path, leaf):
        if getattr(path[0], "key", None) == "blocks":
            return np.asarray(leaf[:, i])
        return np.asarray(leaf[i])
    return jax.tree_util.tree_map_with_path(one, cache)


class TestEngineSlotHygiene:
    def test_freed_slot_cache_stays_inert(self):
        """A finished request's slot must not mutate while other slots keep
        decoding — before the active-mask fix every step ring-wrote the
        idle slot's stale (token 0, pos=cur) entry into its cache."""
        cfg = _cfg("qwen3-4b", recalkv_ratio=0.5)
        params = T.init_params(cfg, KEY)
        g = np.random.default_rng(11)

        eng = Engine(cfg, params, max_slots=2, max_len=37)
        eng.submit(Request(uid=0,
                           prompt=g.integers(0, cfg.vocab_size, 4).astype(np.int32),
                           max_new_tokens=2))
        eng.submit(Request(uid=1,
                           prompt=g.integers(0, cfg.vocab_size, 6).astype(np.int32),
                           max_new_tokens=12))
        eng.step()                  # admits both requests
        while eng.slot_req[0] is not None:
            eng.step()
        frozen = _slot_rows(eng.cache, 0)
        for _ in range(4):          # slot 1 keeps decoding, slot 0 is free
            eng.step()
        after = _slot_rows(eng.cache, 0)
        for a, b in zip(jax.tree.leaves(frozen), jax.tree.leaves(after)):
            np.testing.assert_array_equal(a, b)

    def test_readmission_into_freed_slot_matches_solo(self):
        """A request admitted into a previously-used slot must decode
        exactly as in a fresh single-slot engine."""
        cfg = _cfg("qwen3-4b", recalkv_ratio=0.5)
        params = T.init_params(cfg, KEY)
        g = np.random.default_rng(12)
        late = g.integers(0, cfg.vocab_size, 5).astype(np.int32)

        eng = Engine(cfg, params, max_slots=2, max_len=37)
        eng.submit(Request(uid=0,
                           prompt=g.integers(0, cfg.vocab_size, 4).astype(np.int32),
                           max_new_tokens=2))
        eng.submit(Request(uid=1,
                           prompt=g.integers(0, cfg.vocab_size, 6).astype(np.int32),
                           max_new_tokens=10))
        for _ in range(6):
            eng.step()
        eng.submit(Request(uid=2, prompt=late.copy(), max_new_tokens=6))
        done = {r.uid: r.out_tokens for r in eng.run()}

        solo = Engine(cfg, params, max_slots=1, max_len=37)
        solo.submit(Request(uid=2, prompt=late.copy(), max_new_tokens=6))
        assert done[2] == solo.run()[0].out_tokens

    def test_prefill_shapes_bucketed(self):
        """Ragged admission waves must reuse O(log) prefill traces."""
        cfg = _cfg("qwen3-4b")
        params = T.init_params(cfg, KEY)
        eng = Engine(cfg, params, max_slots=4, max_len=40)
        g = np.random.default_rng(5)
        shapes = set()
        orig = eng._prefill

        def spy(p, t, l):
            shapes.add(tuple(t.shape))
            return orig(p, t, l)

        eng._prefill = spy
        waves = [(1, 3), (2, 5), (3, 6), (1, 7), (4, 9), (2, 11), (3, 13),
                 (1, 17), (2, 19), (4, 21), (3, 23), (1, 26)]
        for n, plen in waves:
            for i in range(n):
                eng.submit(Request(
                    uid=1000 * n + plen * 10 + i,
                    prompt=g.integers(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=1))
            eng.run()
        # every raw (wave, prompt) shape is distinct; buckets collapse them
        assert len(shapes) < len(set(waves))
        for w, p in shapes:
            assert w == w & -w and p == p & -p   # powers of two
