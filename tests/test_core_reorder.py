import numpy as np
import pytest

from repro.core import reorder


@pytest.fixture
def rng():
    return np.random.default_rng(2)


def block_similarity(rng, H, group_size, strength=0.9):
    """Similarity matrix with planted groups scattered over positions."""
    S = rng.random((H, H)) * 0.2
    S = (S + S.T) / 2
    perm = rng.permutation(H)
    for g in range(H // group_size):
        idx = perm[g * group_size:(g + 1) * group_size]
        for a in idx:
            for b in idx:
                S[a, b] = strength + 0.05 * rng.random()
    np.fill_diagonal(S, 1.0)
    return S, perm


class TestGreedyGrouping:
    def test_partition_property(self, rng):
        S, _ = block_similarity(rng, 16, 4)
        groups = reorder.greedy_group_heads(S, 4)
        flat = sorted(h for g in groups for h in g)
        assert flat == list(range(16))
        assert all(len(g) == 4 for g in groups)

    def test_recovers_planted_groups(self, rng):
        S, perm = block_similarity(rng, 16, 4)
        groups = reorder.greedy_group_heads(S, 4)
        planted = {frozenset(perm[i * 4:(i + 1) * 4].tolist())
                   for i in range(4)}
        found = {frozenset(g) for g in groups}
        assert found == planted

    def test_improves_within_group_similarity(self, rng):
        S, _ = block_similarity(rng, 16, 4)
        hsr = reorder.greedy_group_heads(S, 4)
        base = reorder.identity_groups(16, 4)
        assert (reorder.within_group_similarity(S, hsr)
                >= reorder.within_group_similarity(S, base))

    def test_group_size_one(self):
        groups = reorder.greedy_group_heads(np.eye(4), 1)
        assert groups == [[0], [1], [2], [3]]

    def test_indivisible_raises(self):
        with pytest.raises(ValueError):
            reorder.greedy_group_heads(np.eye(6), 4)


class TestPermutation:
    def test_groups_to_permutation_roundtrip(self, rng):
        S, _ = block_similarity(rng, 8, 2)
        groups = reorder.greedy_group_heads(S, 2)
        perm = reorder.groups_to_permutation(groups)
        assert sorted(perm.tolist()) == list(range(8))

    def test_invalid_groups_raise(self):
        with pytest.raises(ValueError):
            reorder.groups_to_permutation([[0, 1], [1, 2]])
