"""Mesh-sharded serving parity.

On a forced multi-device CPU host (``XLA_FLAGS=
--xla_force_host_platform_device_count=8``, see the `mesh` CI job) the
engine on a (data=2, model=4) mesh must emit token streams TOKEN-FOR-TOKEN
equal to the single-device engine — greedy and sampled, dense / latent /
int8-latent caches, full and chunked prefill — and keep the
1-sync-per-window invariant (sharding must not smuggle per-step host
round-trips back in).  With fewer devices every test here skips via the
shared ``make_test_mesh(skip=True)`` guard.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.serving import Engine, Request, SamplingParams
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)

CASES = {
    "dense": {},
    "latent": {"recalkv_ratio": 0.5},
    "int8_latent": {"recalkv_ratio": 0.5, "cache_quant_bits": 8},
}

SAMPLED = SamplingParams(temperature=0.9, top_k=32, top_p=0.9, seed=11)


@pytest.fixture(scope="module")
def mesh24():
    return make_test_mesh(2, 4, skip=True)


def _model(case):
    extra = CASES[case]
    kw = {k: extra[k] for k in ("recalkv_ratio",) if k in extra}
    cfg = get_config("qwen3-4b", smoke=True, **kw)
    cfg = dataclasses.replace(
        cfg, dtype=jnp.float32,
        **{k: v for k, v in extra.items() if k == "cache_quant_bits"})
    return cfg, T.init_params(cfg, KEY)


def _serve(cfg, params, prompts, mesh, sampling=None, max_new=6, **kw):
    eng = Engine(cfg, params, max_slots=4, max_len=40, mesh=mesh,
                 sampling=sampling, **kw)
    for i, pr in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=max_new))
    done = eng.run()
    return {r.uid: r.out_tokens for r in done}, eng


def _prompts(cfg, n=6, seed=3):
    g = np.random.default_rng(seed)
    return [g.integers(0, cfg.vocab_size, 5 + 2 * i).astype(np.int32)
            for i in range(n)]


class TestMeshStreamParity:
    @pytest.mark.parametrize("case", sorted(CASES))
    def test_greedy_streams_match_single_device(self, mesh24, case):
        cfg, params = _model(case)
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts, None)
        got, eng = _serve(cfg, params, prompts, mesh24)
        assert eng.mesh_str == "2x4"
        assert got == ref, case

    @pytest.mark.parametrize("case", sorted(CASES))
    def test_sampled_streams_match_single_device(self, mesh24, case):
        cfg, params = _model(case)
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts, None, sampling=SAMPLED)
        got, _ = _serve(cfg, params, prompts, mesh24, sampling=SAMPLED)
        assert got == ref, case

    def test_mla_streams_match_single_device(self, mesh24):
        """MLA per-head widths differ from d_head — the head_grains map
        must keep wq_b/wkv_a/wkv_b whole under TP (regression for the
        sub-head-tile RoPE hazard on the absorbed-latent path)."""
        cfg = dataclasses.replace(get_config("deepseek-v3-671b", smoke=True),
                                  dtype=jnp.float32)
        params = T.init_params(cfg, KEY)
        prompts = _prompts(cfg, n=4)
        ref, _ = _serve(cfg, params, prompts, None, max_new=5)
        got, _ = _serve(cfg, params, prompts, mesh24, max_new=5)
        assert got == ref
        ref_s, _ = _serve(cfg, params, prompts, None, sampling=SAMPLED,
                          max_new=5)
        got_s, _ = _serve(cfg, params, prompts, mesh24, sampling=SAMPLED,
                          max_new=5)
        assert got_s == ref_s

    def test_one_sync_per_window_on_mesh(self, mesh24):
        """The executor's structural contract survives sharding: exactly
        one harvest per decode window plus one per admission wave."""
        cfg, params = _model("latent")
        _, eng = _serve(cfg, params, _prompts(cfg), mesh24, max_new=16)
        m = eng.metrics()
        assert m["host_syncs"] == m["windows"] + m["admission_syncs"], m
        assert m["host_syncs"] < m["tokens"], m

    def test_cache_pool_is_slot_and_sequence_sharded(self, mesh24):
        """The resident ring is genuinely distributed: slot rows over
        "data", ring positions over "model" (the psum-LSE-merge layout)."""
        cfg, params = _model("latent")
        _, eng = _serve(cfg, params, _prompts(cfg, n=2), mesh24)
        ring_specs = set()
        for leaf in jax.tree.leaves(eng.cache):
            spec = tuple(leaf.sharding.spec)
            if leaf.ndim >= 3:
                ring_specs.add(spec)
        assert ring_specs, "no ring leaves found"
        for spec in ring_specs:
            assert "data" in spec, spec      # slot axis sharded
        assert any("model" in spec for spec in ring_specs), ring_specs


class TestFusedLoopParityMesh:
    """Extends TestFusedLoopParity (test_backend_equiv) to the mesh: the
    chunked-prefill ingest path and non-greedy sampling must be
    stream-invariant to the mesh exactly as they are to sync_every /
    prefill_chunk."""

    @pytest.mark.parametrize("case", sorted(CASES))
    def test_cap_length_chunked_sampled_matches_single_device(self, mesh24,
                                                              case):
        """A cap-length (max_len - 1) prompt admitted in prefill_chunk
        pieces on the mesh, decoded with non-greedy sampling, produces
        the identical stream as unchunked single-device admission."""
        cfg, params = _model(case)
        g = np.random.default_rng(9)
        cap = g.integers(0, cfg.vocab_size, 39).astype(np.int32)

        def serve(mesh, chunk, sync_every=4):
            eng = Engine(cfg, params, max_slots=4, max_len=40, mesh=mesh,
                         sampling=SAMPLED, prefill_chunk=chunk,
                         sync_every=sync_every)
            eng.submit(Request(uid=0, prompt=cap.copy(), max_new_tokens=5))
            return eng.run()[0].out_tokens

        ref = serve(None, None)
        assert serve(mesh24, 7) == ref, case
        assert serve(mesh24, 5, sync_every=3) == ref, case

    def test_mixed_load_chunked_sampled_matches_single_device(self, mesh24):
        """Chunked long prompts + short greedy + sampled requests mixed in
        one slot pool behave identically on and off the mesh."""
        cfg, params = _model("latent")
        g = np.random.default_rng(21)
        reqs = []
        for i in range(6):
            plen = int(g.integers(3, 30))
            sp = SAMPLED if i % 2 else None
            reqs.append((g.integers(0, cfg.vocab_size, plen).astype(np.int32),
                         sp))

        def serve(mesh):
            eng = Engine(cfg, params, max_slots=4, max_len=40, mesh=mesh,
                         prefill_chunk=6, sync_every=4)
            for i, (pr, sp) in enumerate(reqs):
                eng.submit(Request(uid=i, prompt=pr.copy(),
                                   max_new_tokens=6, sampling=sp))
            return {r.uid: r.out_tokens for r in eng.run()}

        assert serve(mesh24) == serve(None)


class TestMeshSpeculation:
    """Speculative decoding composes with the sharded window: the accept
    mask / key chain / fed-token history are ordinary slot-sharded carry
    leaves, the draft rings follow CACHE_RULES — streams must equal the
    single-device spec_depth=0 engine token-for-token, and the window
    still costs one sync however many tokens it verifies."""

    @pytest.mark.parametrize("depth", [2, 4])
    @pytest.mark.parametrize("policy", ["greedy", "sampled"])
    def test_ngram_streams_match_unspeculated_single_device(self, mesh24,
                                                            policy, depth):
        cfg, params = _model("latent")
        sp = None if policy == "greedy" else SAMPLED
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts, None, sampling=sp)
        got, eng = _serve(cfg, params, prompts, mesh24, sampling=sp,
                          spec_depth=depth, draft="ngram")
        assert got == ref, (policy, depth)
        m = eng.metrics()
        assert m["host_syncs"] == m["windows"] + m["admission_syncs"], m

    @pytest.mark.parametrize("case", ["dense", "int8_latent"])
    def test_variants_spec_depth_2_on_mesh(self, mesh24, case):
        cfg, params = _model(case)
        prompts = _prompts(cfg, n=4)
        ref, _ = _serve(cfg, params, prompts, None, sampling=SAMPLED)
        got, _ = _serve(cfg, params, prompts, mesh24, sampling=SAMPLED,
                        spec_depth=2, draft="ngram")
        assert got == ref, case

    def test_layer_draft_on_mesh(self, mesh24):
        """The layer-fraction draft threads a second (param, ring) pair
        through the window; its shardings follow the same rules, so the
        mesh stream must still match single-device unspeculated."""
        cfg, params = _model("latent")
        prompts = _prompts(cfg)
        for sp in (None, SAMPLED):
            ref, _ = _serve(cfg, params, prompts, None, sampling=sp)
            got, eng = _serve(cfg, params, prompts, mesh24, sampling=sp,
                              spec_depth=2, draft="layers:2")
            assert got == ref
            assert eng.metrics()["draft_proposed"] > 0


class TestMeshAdmission:
    def test_shard_aware_waves_fill_one_shard_group(self, mesh24):
        """With 4 slots over data=2, a 2-request wave lands on one
        addressable shard's rows (slots {0,1} or {2,3})."""
        cfg, params = _model("latent")
        g = np.random.default_rng(5)
        eng = Engine(cfg, params, max_slots=4, max_len=40, mesh=mesh24)
        assert eng.scheduler.slot_shards == 2
        for i in range(2):
            eng.submit(Request(
                uid=i, prompt=g.integers(0, cfg.vocab_size, 5).astype(np.int32),
                max_new_tokens=20))
        eng.step()
        taken = [i for i, r in enumerate(eng.slot_req) if r is not None]
        assert taken in ([0, 1], [2, 3])
