"""repro.api surface: registry, specs, strategies, durable artifacts."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CalibrationData,
    CompressionArtifact,
    CompressionSpec,
    RankPolicy,
    calibrate,
    compress,
    get_strategy,
    list_strategies,
    load_artifact,
    register_strategy,
    save_artifact,
    serve,
    unregister_strategy,
)
from repro.configs import get_config
from repro.core import ReCalKVConfig
from repro.models import transformer as T
from repro.serving import Engine, Request

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense_model():
    cfg = dataclasses.replace(get_config("qwen3-4b", smoke=True),
                              dtype=jnp.float32, scan_layers=False)
    return cfg, T.init_params(cfg, KEY)


@pytest.fixture(scope="module")
def calib_batches(dense_model):
    cfg, _ = dense_model
    g = np.random.default_rng(0)
    return [{"tokens": jnp.asarray(g.integers(0, cfg.vocab_size, (2, 32))),
             "labels": jnp.asarray(g.integers(0, cfg.vocab_size, (2, 32)))}
            for _ in range(2)]


@pytest.fixture(scope="module")
def calib(dense_model, calib_batches):
    cfg, params = dense_model
    return calibrate(cfg, params, calib_batches, fisher=True)


class TestRegistry:
    def test_builtin_strategies_present(self):
        names = list_strategies()
        assert len(names) >= 4
        for required in ("recalkv", "grouped-svd", "whitened-svd",
                         "quantized-latent"):
            assert required in names

    def test_get_unknown_raises(self):
        with pytest.raises(KeyError, match="unknown compression strategy"):
            get_strategy("nope")

    def test_register_custom_strategy(self, dense_model, calib):
        cfg, params = dense_model

        class Passthrough:
            name = "passthrough-test"

            def compress(self, cfg, params, spec, calib):
                return cfg, params, {"custom": True}

        register_strategy(Passthrough)
        try:
            assert "passthrough-test" in list_strategies()
            with pytest.raises(ValueError, match="already registered"):
                register_strategy(Passthrough)
            art = compress(cfg, params, "passthrough-test", calib)
            assert art.provenance["custom"] is True
            assert art.cfg is cfg
        finally:
            unregister_strategy("passthrough-test")
        assert "passthrough-test" not in list_strategies()

    def test_unknown_option_rejected(self, dense_model, calib):
        cfg, params = dense_model
        with pytest.raises(ValueError, match="unknown options"):
            compress(cfg, params,
                     CompressionSpec("recalkv", options={"bogus": 1}), calib)

    def test_data_aware_strategy_needs_calibration(self, dense_model):
        cfg, params = dense_model
        with pytest.raises(ValueError, match="calibration"):
            compress(cfg, params, "whitened-svd")


class TestSpec:
    def test_rank_policy_honors_multiple_and_floor(self):
        pol = RankPolicy(keep_ratio=0.5, rank_multiple=16, min_rank=16)
        assert pol.rank_for_width(64) == 32
        assert pol.rank_for_width(40) == 16     # rounded to the multiple
        assert RankPolicy(keep_ratio=0.07, min_rank=24).rank_for_width(64) == 24

    def test_recalkv_config_rank_for_width_matches_policy(self):
        # the internal config and the public policy share the rank rule,
        # including multiple/floor (cross-attention fallback fix)
        rc = ReCalKVConfig(keep_ratio=0.4, rank_multiple=4, min_rank=12)
        pol = RankPolicy(keep_ratio=0.4, rank_multiple=4, min_rank=12)
        for width in (32, 48, 64, 100):
            assert rc.rank_for_width(width) == pol.rank_for_width(width)

    def test_cross_attention_fallback_honors_rank_policy(self):
        """A cross-attention-only model hits compress_model's fallback rank
        path, which must respect rank_multiple/min_rank (it used to call
        the rank helper with defaults)."""
        import repro.models.compress as C
        from repro.models.config import ModelConfig

        cfg = ModelConfig(
            name="cross-only", family="vlm", num_layers=2, d_model=64,
            num_heads=4, num_kv_heads=4, d_head=16, d_ff=128, vocab_size=64,
            layer_pattern=("cross",), cross_source_len=8,
            dtype=jnp.float32, scan_layers=False, remat=False)
        params = T.init_params(cfg, KEY)
        rc = ReCalKVConfig(keep_ratio=0.1, group_size=2, rank_multiple=8,
                           min_rank=12, use_fisher=False)
        _, cparams = C.compress_model(cfg, params, [], rc)
        # width 32 at keep 0.1 rounds to 0; the floor must lift it to 12
        assert cparams["prefix"][0]["cross"]["l_k"].shape[-1] == 12

    def test_spec_round_trips_through_dict(self):
        spec = CompressionSpec("quantized-latent",
                               options={"base": "grouped-svd", "bits": 4},
                               rank_policy=RankPolicy(keep_ratio=0.3))
        assert CompressionSpec.from_dict(spec.to_dict()) == spec

    def test_invalid_keep_ratio_rejected(self):
        with pytest.raises(ValueError, match="keep_ratio"):
            RankPolicy(keep_ratio=0.0)


class TestStrategies:
    def test_grouped_svd_needs_no_calibration(self, dense_model):
        cfg, params = dense_model
        art = compress(cfg, params, CompressionSpec(
            "grouped-svd", rank_policy=RankPolicy(keep_ratio=0.5)))
        assert art.cfg.recalkv is not None
        assert art.provenance["calib_tokens"] == 0

    def test_fisher_allocation_varies_ranks(self, dense_model, calib):
        cfg, params = dense_model
        art = compress(cfg, params, CompressionSpec(
            "recalkv", rank_policy=RankPolicy(keep_ratio=0.5, use_fisher=True)),
            calib)
        ranks = art.provenance["ranks_by_layer"]
        assert len(ranks) == cfg.num_layers
        assert art.provenance["fisher"] is True

    def test_quantized_latent_composes(self, dense_model, calib):
        cfg, params = dense_model
        pol = RankPolicy(keep_ratio=0.5)
        base = compress(cfg, params,
                        CompressionSpec("recalkv", rank_policy=pol), calib)
        for hadamard in (False, True):
            art = compress(cfg, params, CompressionSpec(
                "quantized-latent",
                options={"base": "recalkv", "bits": 8, "hadamard": hadamard},
                rank_policy=pol), calib)
            assert art.provenance["base"] == "recalkv"
            assert art.provenance["bits"] == 8
            # same latent geometry as the base strategy
            assert art.cfg.recalkv == base.cfg.recalkv
            # 8-bit factor quantization stays close to the fp base model
            toks = jnp.asarray(np.arange(24).reshape(2, 12) % cfg.vocab_size)
            l_fp = T.logits_for(base.cfg, base.params,
                                T.forward_hidden(base.cfg, base.params, toks)[0])
            l_q = T.logits_for(art.cfg, art.params,
                               T.forward_hidden(art.cfg, art.params, toks)[0])
            assert bool(jnp.all(jnp.isfinite(l_q)))
            agree = float(jnp.mean(
                (jnp.argmax(l_fp, -1) == jnp.argmax(l_q, -1))))
            assert agree >= 0.9, f"hadamard={hadamard}: agreement {agree}"

    def test_quantized_latent_rejects_self_wrap(self, dense_model, calib):
        cfg, params = dense_model
        with pytest.raises(ValueError, match="cannot wrap itself"):
            compress(cfg, params, CompressionSpec(
                "quantized-latent", options={"base": "quantized-latent"}),
                calib)


class TestArtifactRoundTrip:
    def test_save_load_bitwise_logits(self, dense_model, calib, tmp_path):
        cfg, params = dense_model
        art = compress(cfg, params, CompressionSpec(
            "recalkv", rank_policy=RankPolicy(keep_ratio=0.5)), calib)
        save_artifact(art, str(tmp_path / "art"))
        loaded = load_artifact(str(tmp_path / "art"))

        assert isinstance(loaded, CompressionArtifact)
        assert loaded.cfg == art.cfg
        assert loaded.method == "recalkv"
        assert loaded.provenance["calib_tokens"] == calib.token_count

        toks = jnp.asarray(np.arange(32).reshape(2, 16) % cfg.vocab_size)
        l_mem = T.logits_for(art.cfg, art.params,
                             T.forward_hidden(art.cfg, art.params, toks)[0])
        l_disk = T.logits_for(loaded.cfg, loaded.params,
                              T.forward_hidden(loaded.cfg, loaded.params,
                                               toks)[0])
        np.testing.assert_array_equal(np.asarray(l_mem), np.asarray(l_disk))

    def test_engine_from_artifact_matches_in_memory(self, dense_model, calib,
                                                    tmp_path):
        cfg, params = dense_model
        art = compress(cfg, params, CompressionSpec(
            "recalkv", rank_policy=RankPolicy(keep_ratio=0.5)), calib)
        save_artifact(art, str(tmp_path / "art"))

        g = np.random.default_rng(3)
        prompts = [g.integers(0, cfg.vocab_size, 5 + i).astype(np.int32)
                   for i in range(3)]

        def serve(eng):
            for i, p in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=p.copy(), max_new_tokens=4))
            return {r.uid: r.out_tokens for r in eng.run()}

        mem = serve(Engine(art.cfg, art.params, max_slots=2, max_len=48))
        disk = serve(Engine.from_artifact(str(tmp_path / "art"),
                                          max_slots=2, max_len=48))
        assert mem == disk

    def test_serve_verb_matches_engine(self, dense_model, calib, tmp_path):
        """repro.api.serve boots the same engine from an in-memory
        artifact or a saved path — the third verb of the facade."""
        import repro.api as api

        cfg, params = dense_model
        art = compress(cfg, params, CompressionSpec(
            "recalkv", rank_policy=RankPolicy(keep_ratio=0.5)), calib)
        save_artifact(art, str(tmp_path / "art"))

        g = np.random.default_rng(4)
        prompts = [g.integers(0, cfg.vocab_size, 5 + i).astype(np.int32)
                   for i in range(2)]

        def drive(eng):
            for i, p in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=p.copy(), max_new_tokens=4))
            return {r.uid: r.out_tokens for r in eng.run()}

        ref = drive(Engine(art.cfg, art.params, max_slots=2, max_len=48))
        mem_eng = api.serve(art, max_slots=2, max_len=48)
        assert mem_eng.mesh_str == "1x1"      # degenerate-mesh default
        assert drive(mem_eng) == ref
        assert drive(api.serve(str(tmp_path / "art"),
                               max_slots=2, max_len=48)) == ref

    def test_load_missing_and_wrong_kind(self, tmp_path, dense_model):
        with pytest.raises(FileNotFoundError):
            load_artifact(str(tmp_path / "absent"))
        # a plain training checkpoint is not an artifact
        from repro import checkpoint as ckpt
        ckpt.save(str(tmp_path / "plain"), 0, {"x": jnp.zeros((2,))})
        with pytest.raises(ValueError, match="not a compression artifact"):
            load_artifact(str(tmp_path / "plain"))

    def test_save_refuses_training_checkpoint_dir(self, tmp_path, dense_model,
                                                  calib):
        """save_artifact must never trim or overwrite a checkpoint run."""
        from repro import checkpoint as ckpt
        cfg, params = dense_model
        art = compress(cfg, params, CompressionSpec(
            "grouped-svd", rank_policy=RankPolicy(keep_ratio=0.5)))
        ckpt.save(str(tmp_path / "run"), 100, {"x": jnp.zeros((2,))})
        with pytest.raises(ValueError, match="refusing to overwrite"):
            save_artifact(art, str(tmp_path / "run"))
        assert ckpt.latest_step(str(tmp_path / "run")) == 100
        ckpt.save(str(tmp_path / "run0"), 0, {"x": jnp.zeros((2,))})
        with pytest.raises(ValueError, match="refusing to overwrite"):
            save_artifact(art, str(tmp_path / "run0"))
        # re-saving over an existing artifact is fine
        save_artifact(art, str(tmp_path / "art"))
        save_artifact(art, str(tmp_path / "art"))
        assert load_artifact(str(tmp_path / "art")).method == "grouped-svd"

    def test_fisher_policy_without_fisher_data_raises(self, dense_model,
                                                      calib_batches):
        cfg, params = dense_model
        no_fisher = calibrate(cfg, params, calib_batches, fisher=False)
        with pytest.raises(ValueError, match="no Fisher scores"):
            compress(cfg, params, CompressionSpec(
                "recalkv", rank_policy=RankPolicy(use_fisher=True)),
                no_fisher)

    def test_artifact_preserves_per_layer_ranks(self, dense_model, calib,
                                                tmp_path):
        cfg, params = dense_model
        art = compress(cfg, params, CompressionSpec(
            "recalkv", rank_policy=RankPolicy(keep_ratio=0.5, use_fisher=True)),
            calib)
        save_artifact(art, str(tmp_path / "art"))
        loaded = load_artifact(str(tmp_path / "art"))
        assert loaded.cfg.recalkv.ranks_by_layer == art.cfg.recalkv.ranks_by_layer
