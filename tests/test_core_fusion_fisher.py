import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fisher, fusion


@pytest.fixture
def rng():
    return np.random.default_rng(4)


class TestPermutationFolding:
    @pytest.mark.parametrize("Hq,Hkv", [(8, 8), (8, 4), (8, 2), (6, 6)])
    def test_folded_attention_is_equivalent(self, rng, Hq, Hkv):
        d, dh, T = 32, 8, 10
        Wq = jnp.asarray(rng.normal(size=(d, Hq * dh)), jnp.float32)
        Wk = jnp.asarray(rng.normal(size=(d, Hkv * dh)), jnp.float32)
        Wv = jnp.asarray(rng.normal(size=(d, Hkv * dh)), jnp.float32)
        Wo = jnp.asarray(rng.normal(size=(Hq * dh, d)), jnp.float32)
        perm = rng.permutation(Hkv)

        def attn(wq, wk, wv, wo, x):
            g = Hq // Hkv
            q = (x @ wq).reshape(T, Hkv, g, dh)
            k = (x @ wk).reshape(T, Hkv, dh)
            v = (x @ wv).reshape(T, Hkv, dh)
            s = jnp.einsum("qkgd,skd->kgqs", q, k) / dh ** 0.5
            a = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("kgqs,skd->qkgd", a, v)
            return o.reshape(T, Hq * dh) @ wo

        x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
        ref = attn(Wq, Wk, Wv, Wo, x)
        Wq2, Wk2, Wv2, Wo2 = fusion.fold_head_permutation(
            Wq, Wk, Wv, Wo, perm, Hq, Hkv)
        out = attn(Wq2, Wk2, Wv2, Wo2, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_inverse_permutation(self, rng):
        perm = rng.permutation(12)
        inv = fusion.inverse_permutation(perm)
        np.testing.assert_array_equal(perm[inv], np.arange(12))
        np.testing.assert_array_equal(inv[perm], np.arange(12))


class TestFusion:
    def test_fused_projection_identity(self, rng):
        """sum_h (A_h z_g) (R^(h) W_o^(h)) == sum_h (A_h V_h) W_o^(h)."""
        Hq, Hkv, s, dh, d, r, S = 8, 4, 2, 8, 32, 12, 20
        G = Hkv // s
        R_v = jnp.asarray(rng.normal(size=(G, r, s * dh)), jnp.float32)
        W_o = jnp.asarray(rng.normal(size=(Hq * dh, d)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(S, G, r)), jnp.float32)
        A = jax.nn.softmax(
            jnp.asarray(rng.normal(size=(Hq, S)), jnp.float32), -1)

        # reference: reconstruct V per kv head, attend, project densely
        v = jnp.einsum("sgr,grn->sgn", z, R_v).reshape(S, Hkv, dh)
        qpk = Hq // Hkv
        o = jnp.stack([A[h] @ v[:, h // qpk] for h in range(Hq)])  # (Hq, dh)
        ref = o.reshape(1, Hq * dh) @ W_o

        W_f = fusion.fuse_output_projection(R_v, W_o, Hq, Hkv)
        o_lat = jnp.stack(
            [A[h] @ z[:, (h // qpk) // s] for h in range(Hq)])      # (Hq, r)
        out = fusion.fused_output_apply(o_lat[None], W_f)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_fused_shape(self, rng):
        R_v = jnp.ones((2, 6, 16), jnp.float32)
        W_o = jnp.ones((8 * 8, 24), jnp.float32)
        W_f = fusion.fuse_output_projection(R_v, W_o, 8, 4)
        assert W_f.shape == (8, 6, 24)


class TestFisher:
    def test_allocation_meets_budget(self, rng):
        scores = rng.random(12).tolist()
        ratios = fisher.allocate_ratios(scores, 0.5)
        assert np.mean(ratios) == pytest.approx(0.5, abs=1e-6)
        assert all(0.0625 <= r <= 1.0 for r in ratios)

    def test_monotone_in_scores(self, rng):
        scores = sorted(rng.random(8).tolist())
        ratios = fisher.allocate_ratios(scores, 0.4)
        assert all(a <= b + 1e-9 for a, b in zip(ratios, ratios[1:]))

    def test_extreme_budget_clips(self):
        ratios = fisher.allocate_ratios([1.0, 2.0, 3.0], 1.0)
        assert ratios == pytest.approx([1.0, 1.0, 1.0])

    def test_rank_rounding(self):
        alloc = fisher.allocate([1.0, 4.0], 0.5, 256)
        assert all(r % 8 == 0 for r in alloc.ranks)
        assert alloc.ranks[0] <= alloc.ranks[1]

    def test_empirical_fisher_shapes(self, rng):
        params = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}

        def loss(p, b):
            return jnp.sum((b @ p["w"]) ** 2)

        batches = [jnp.asarray(rng.normal(size=(3, 4)), jnp.float32)
                   for _ in range(2)]
        f = fisher.empirical_fisher(loss, params, batches)
        assert f["w"].shape == (4, 4)
        assert bool(jnp.all(f["w"] >= 0))
