"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles,
executed with interpret=True (kernel bodies run in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_prefill import flash_prefill_attention
from repro.kernels.latent_decode import latent_decode_attention
from repro.kernels.latent_decode_q import latent_decode_attention_quant


def rnd(rng, *shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


def latent_inputs(rng, B, S, G, rk, rv, s, qpk, dh, dtype):
    Hg = s * qpk
    q = rnd(rng, B, G, Hg, dh, dtype=dtype)
    zk = rnd(rng, B, S, G, rk, dtype=dtype)
    zv = rnd(rng, B, S, G, rv, dtype=dtype)
    r_k = rnd(rng, G, rk, s * dh, dtype=dtype, scale=rk ** -0.5)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    cur = jnp.asarray([S - 1] * B)
    cos, sin = ops.rope_tables_for(pos, dh, 1e4)
    bias = ops.decode_bias(pos, cur, None)
    return q, zk, zv, r_k, cos.astype(dtype), sin.astype(dtype), bias


SWEEP = [
    # B, S, G, rk, rv, s, qpk, dh
    (1, 128, 1, 16, 16, 1, 4, 16),     # MQA degenerate group
    (2, 256, 2, 32, 24, 2, 2, 16),     # uneven rk/rv
    (2, 256, 2, 32, 32, 4, 1, 8),      # MHA groups of 4
    (1, 512, 1, 64, 48, 4, 4, 32),     # GQA 16q/4kv single group
    (3, 384, 3, 24, 24, 2, 3, 8),      # odd batch/groups/heads
]


class TestLatentDecode:
    @pytest.mark.parametrize("B,S,G,rk,rv,s,qpk,dh", SWEEP)
    def test_matches_oracle(self, B, S, G, rk, rv, s, qpk, dh):
        rng = np.random.default_rng(hash((B, S, G, rk)) % 2**31)
        q, zk, zv, r_k, cos, sin, bias = latent_inputs(
            rng, B, S, G, rk, rv, s, qpk, dh, jnp.float32)
        o_ref = ref.latent_decode_attention(q, zk, zv, r_k, cos, sin, bias,
                                            dh ** -0.5)
        o_ker = latent_decode_attention(q, zk, zv, r_k, cos, sin, bias,
                                        scale=dh ** -0.5, block_s=128,
                                        interpret=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16_inputs(self):
        rng = np.random.default_rng(9)
        q, zk, zv, r_k, cos, sin, bias = latent_inputs(
            rng, 2, 256, 2, 16, 16, 2, 2, 16, jnp.bfloat16)
        o_ref = ref.latent_decode_attention(q, zk, zv, r_k, cos, sin, bias, 0.25)
        o_ker = latent_decode_attention(q, zk, zv, r_k, cos, sin, bias,
                                        scale=0.25, block_s=128, interpret=True)
        np.testing.assert_allclose(
            np.asarray(o_ker, np.float32), np.asarray(o_ref, np.float32),
            rtol=0.05, atol=0.05)

    def test_masked_positions_ignored(self):
        """Ring slots beyond cur (or empty) must not affect the output."""
        rng = np.random.default_rng(10)
        B, S = 2, 256
        q, zk, zv, r_k, cos, sin, _ = latent_inputs(
            rng, B, S, 2, 16, 16, 2, 2, 16, jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        cur = jnp.asarray([100, 200])
        bias = ops.decode_bias(pos, cur, None)
        o1 = latent_decode_attention(q, zk, zv, r_k, cos, sin, bias,
                                     scale=0.25, block_s=128, interpret=True)
        # scramble the masked tail; output must not change
        zk2 = zk.at[:, 201:].set(99.0)
        zv2 = zv.at[:, 201:].set(-99.0)
        o2 = latent_decode_attention(q, zk2, zv2, r_k, cos, sin, bias,
                                     scale=0.25, block_s=128, interpret=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)

    def test_windowed_bias(self):
        rng = np.random.default_rng(11)
        q, zk, zv, r_k, cos, sin, _ = latent_inputs(
            rng, 1, 256, 2, 16, 16, 2, 2, 16, jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(256), (1, 256))
        cur = jnp.asarray([255])
        bias = ops.decode_bias(pos, cur, window=64)
        o_ref = ref.latent_decode_attention(q, zk, zv, r_k, cos, sin, bias, 0.25)
        o_ker = latent_decode_attention(q, zk, zv, r_k, cos, sin, bias,
                                        scale=0.25, block_s=64, interpret=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)


class TestLatentDecodeQuant:
    @pytest.mark.parametrize("B,S,G,rk,rv,s,qpk,dh", SWEEP[:3])
    def test_matches_oracle(self, B, S, G, rk, rv, s, qpk, dh):
        rng = np.random.default_rng(12)
        q, zk, zv, r_k, cos, sin, bias = latent_inputs(
            rng, B, S, G, rk, rv, s, qpk, dh, jnp.float32)
        from repro.quant import quantize
        zk_q, zk_s = quantize(zk, 8)
        zv_q, zv_s = quantize(zv, 8)
        zk_s, zv_s = zk_s[..., 0], zv_s[..., 0]
        o_ref = ref.latent_decode_attention_quant(
            q, zk_q, zk_s, zv_q, zv_s, r_k, cos, sin, bias, dh ** -0.5)
        o_ker = latent_decode_attention_quant(
            q, zk_q, zk_s, zv_q, zv_s, r_k, cos, sin, bias,
            scale=dh ** -0.5, block_s=128, interpret=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_quantized_close_to_fp(self):
        rng = np.random.default_rng(13)
        q, zk, zv, r_k, cos, sin, bias = latent_inputs(
            rng, 1, 128, 2, 16, 16, 2, 2, 16, jnp.float32)
        from repro.quant import quantize
        zk_q, zk_s = quantize(zk, 8)
        zv_q, zv_s = quantize(zv, 8)
        o_fp = ref.latent_decode_attention(q, zk, zv, r_k, cos, sin, bias, 0.25)
        o_q = latent_decode_attention_quant(
            q, zk_q, zk_s[..., 0], zv_q, zv_s[..., 0], r_k, cos, sin, bias,
            scale=0.25, block_s=128, interpret=True)
        rel = float(jnp.linalg.norm(o_fp - o_q) / jnp.linalg.norm(o_fp))
        assert rel < 0.05


class TestFlashPrefill:
    @pytest.mark.parametrize("B,T,H,Hkv,dh,win", [
        (1, 128, 4, 4, 16, None),
        (2, 256, 4, 2, 16, None),
        (2, 256, 8, 2, 8, 64),
        (1, 512, 2, 1, 32, 128),
    ])
    def test_matches_oracle(self, B, T, H, Hkv, dh, win):
        rng = np.random.default_rng(14)
        q = rnd(rng, B, T, H, dh)
        k = rnd(rng, B, T, Hkv, dh)
        v = rnd(rng, B, T, Hkv, dh)
        o_ref = ref.flash_prefill_attention(q, k, v, causal=True, window=win)
        o_ker = flash_prefill_attention(q, k, v, causal=True, window=win,
                                        block_q=64, block_k=64, interpret=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_bidirectional(self):
        rng = np.random.default_rng(15)
        q, k, v = (rnd(rng, 2, 128, 4, 16) for _ in range(3))
        o_ref = ref.flash_prefill_attention(q, k, v, causal=False)
        o_ker = flash_prefill_attention(q, k, v, causal=False, block_q=64,
                                        block_k=64, interpret=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_matches_model_layer_semantics(self):
        """Kernel == the model's chunked_attention (same masking rules)."""
        from repro.models import layers as L
        rng = np.random.default_rng(16)
        B, T, H, dh = 1, 128, 4, 16
        q, k, v = (rnd(rng, B, T, H, dh) for _ in range(3))
        pos = jnp.broadcast_to(jnp.arange(T), (B, T))
        o_model = L.chunked_attention(q, k, v, pos, pos, window=32,
                                      scale=dh ** -0.5, chunk=64)
        o_ker = flash_prefill_attention(q, k, v, causal=True, window=32,
                                        block_q=64, block_k=64, interpret=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_model),
                                   rtol=2e-4, atol=2e-4)


class TestOpsWrapper:
    def test_latent_decode_end_to_end_vs_model(self):
        """ops.latent_decode over a model cache == kv_cache.decode_attn_latent
        score/value semantics (up to the fused projection)."""
        rng = np.random.default_rng(17)
        B, S, G, rk, rv, s, qpk, dh = 2, 128, 2, 16, 16, 2, 2, 16
        H = G * s * qpk
        cache = {
            "zk": rnd(rng, B, S, G, rk),
            "zv": rnd(rng, B, S, G, rv),
            "pos": jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32),
        }
        q = rnd(rng, B, H, dh)
        r_k = rnd(rng, G, rk, s * dh, scale=rk ** -0.5)
        cur = jnp.asarray([S - 1, 77])
        out_k = ops.latent_decode(q, cache, r_k, cur, theta=1e4, window=None,
                                  scale=dh ** -0.5, block_s=64,
                                  use_kernel=True, interpret=True)
        out_r = ops.latent_decode(q, cache, r_k, cur, theta=1e4, window=None,
                                  scale=dh ** -0.5, use_kernel=False)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=2e-4, atol=2e-4)
        assert out_k.shape == (B, H, rv)


class TestTailTiles:
    """Ring/sequence lengths not divisible by the tile size: the kernels
    must pad and mask the tail internally (the engine's max_len is
    user-chosen and rarely a multiple of 256)."""

    @pytest.mark.parametrize("S", [100, 300, 129])
    def test_latent_decode_ragged_ring(self, S):
        rng = np.random.default_rng(S)
        q, zk, zv, r_k, cos, sin, bias = latent_inputs(
            rng, 2, S, 2, 16, 16, 2, 2, 16, jnp.float32)
        o_ref = ref.latent_decode_attention(q, zk, zv, r_k, cos, sin, bias, 0.25)
        o_ker = latent_decode_attention(q, zk, zv, r_k, cos, sin, bias,
                                        scale=0.25, block_s=64, interpret=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_latent_decode_quant_ragged_ring(self):
        from repro.quant import quantize
        rng = np.random.default_rng(21)
        q, zk, zv, r_k, cos, sin, bias = latent_inputs(
            rng, 2, 150, 2, 16, 16, 2, 2, 16, jnp.float32)
        zk_q, zk_s = quantize(zk, 8)
        zv_q, zv_s = quantize(zv, 8)
        o_ref = ref.latent_decode_attention_quant(
            q, zk_q, zk_s[..., 0], zv_q, zv_s[..., 0], r_k, cos, sin, bias, 0.25)
        o_ker = latent_decode_attention_quant(
            q, zk_q, zk_s[..., 0], zv_q, zv_s[..., 0], r_k, cos, sin, bias,
            scale=0.25, block_s=64, interpret=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("T,win", [(100, None), (200, 48), (70, None)])
    def test_flash_prefill_ragged_seq(self, T, win):
        rng = np.random.default_rng(T)
        q = rnd(rng, 2, T, 4, 16)
        k = rnd(rng, 2, T, 2, 16)
        v = rnd(rng, 2, T, 2, 16)
        o_ref = ref.flash_prefill_attention(q, k, v, causal=True, window=win)
        o_ker = flash_prefill_attention(q, k, v, causal=True, window=win,
                                        block_q=64, block_k=64, interpret=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_flash_prefill_latent_values(self):
        """v may carry G latent groups instead of Hkv heads (latent
        prefill: value group = query head // (H // G))."""
        rng = np.random.default_rng(23)
        B, T, H, Hkv, G, dh, rv = 1, 96, 8, 4, 2, 16, 12
        q = rnd(rng, B, T, H, dh)
        k = rnd(rng, B, T, Hkv, dh)
        zv = rnd(rng, B, T, G, rv)
        from repro.models import layers as L
        pos = jnp.broadcast_to(jnp.arange(T), (B, T))
        o_model = L.chunked_attention(q, k, zv, pos, pos, window=None,
                                      scale=dh ** -0.5, chunk=48,
                                      latent_v=True, group_size=Hkv // G)
        o_ker = flash_prefill_attention(q, k, zv, causal=True, block_q=32,
                                        block_k=32, interpret=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_model),
                                   rtol=2e-4, atol=2e-4)

    def test_latent_decode_knorm(self):
        """In-kernel qk-norm == reconstruct -> rmsnorm -> rope reference."""
        from repro.models import layers as L
        rng = np.random.default_rng(25)
        B, S, G, rk, rv, s, qpk, dh = 1, 96, 1, 16, 16, 2, 2, 16
        q, zk, zv, r_k, cos, sin, bias = latent_inputs(
            rng, B, S, G, rk, rv, s, qpk, dh, jnp.float32)
        kn = rnd(rng, dh, scale=0.1)
        # oracle: norm the reconstructed (pre-RoPE) keys, then defer to ref
        k = jnp.einsum("bsgr,grn->bsgn", zk, r_k).reshape(B, S, G * s, dh)
        k = L.rmsnorm(k, kn)
        zk_n = k.reshape(B, S, G, s * dh)
        eye = jnp.broadcast_to(jnp.eye(s * dh, dtype=zk.dtype), (G, s * dh, s * dh))
        o_ref = ref.latent_decode_attention(q, zk_n, zv, eye, cos, sin, bias, 0.25)
        o_ker = latent_decode_attention(q, zk, zv, r_k, cos, sin, bias,
                                        scale=0.25, block_s=32, interpret=True,
                                        k_norm=kn)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)
