"""Multi-query verify kernel parity: ``backend="pallas"`` must be a pure
perf knob for speculative serving.

The pallas verify path scores all S = spec_depth + 1 queries against
[ring | causal self block] in ONE kernel pass with a joint softmax that
matches the einsum reader's ``_joint_softmax`` at the logit level — so
verify logits agree to float32 rounding and served token streams are
TOKEN-FOR-TOKEN equal to the einsum backend, across cache variants
(dense / latent / int8-latent), layouts (ring / paged), depths, and
meshes.  On a forced multi-device host (``XLA_FLAGS=
--xla_force_host_platform_device_count=8``) the kernels additionally run
under shard_map over the mesh's "model" axis (per-shard partial softmax,
LSE merge); with fewer devices those tests skip via
``make_test_mesh(skip=True)``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T
from repro.serving import Engine, Request

KEY = jax.random.PRNGKey(0)

CASES = {
    "dense": {},
    "latent": {"recalkv_ratio": 0.5},
    "int8_latent": {"recalkv_ratio": 0.5, "cache_quant_bits": 8},
}


def _model(case):
    extra = dict(CASES[case])
    kw = {k: extra.pop(k) for k in ("recalkv_ratio",) if k in extra}
    cfg = get_config("qwen3-4b", smoke=True, **kw)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, **extra)
    return cfg, T.init_params(cfg, KEY)


@pytest.fixture(scope="module")
def models():
    return {case: _model(case) for case in CASES}


@pytest.fixture(scope="module")
def mesh24():
    return make_test_mesh(2, 4, skip=True)


def _prompts(cfg, n=4, seed=3):
    g = np.random.default_rng(seed)
    return [g.integers(0, cfg.vocab_size, 5 + 2 * i).astype(np.int32)
            for i in range(n)]


def _serve(cfg, params, prompts, max_new=6, max_len=40, **kw):
    eng = Engine(cfg, params, max_slots=4, max_len=max_len, **kw)
    for i, pr in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=max_new))
    eng.run()
    return {r.uid: r.out_tokens for r in eng.finished}, eng


class TestVerifyStepLogits:
    """T.verify_step pallas vs einsum at the logit level, including a
    feed-masked column (the masked column's logits are garbage on both
    paths and excluded)."""

    @pytest.mark.parametrize("depth", [2, 4])
    @pytest.mark.parametrize("case", list(CASES))
    def test_logits_match_einsum(self, models, case, depth):
        cfg, params = models[case]
        cfg_p = dataclasses.replace(cfg, attn_backend="pallas")
        rng = np.random.default_rng(7)
        B, P, S = 2, 6, depth + 1
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)),
                           jnp.int32)
        lens = jnp.asarray([P, 4], jnp.int32)
        _, caches = T.prefill(cfg, params, toks, lens, 37)
        cur = lens.astype(jnp.int32)
        fed = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                          jnp.int32)
        fm = jnp.ones((B, S), bool).at[1, S - 1].set(False)
        lg_e, _ = T.verify_step(cfg, params, caches, fed, cur, fm)
        lg_p, _ = T.verify_step(cfg_p, params, caches, fed, cur, fm)
        diff = float(jnp.max(jnp.abs(lg_e - lg_p) * fm[..., None]))
        assert diff < 2e-5, f"verify logits diverge: {diff}"
        tok_e = jnp.argmax(lg_e, -1)
        tok_p = jnp.argmax(lg_p, -1)
        assert bool(jnp.all(jnp.where(fm, tok_e == tok_p, True)))


class TestServingStreamParity:
    """Engine streams: every (variant, layout, depth) pallas stream must
    equal its einsum twin token for token."""

    @pytest.mark.parametrize("depth", [2, 4])
    @pytest.mark.parametrize("layout", ["ring", "paged"])
    @pytest.mark.parametrize("case", list(CASES))
    def test_stream_matches_einsum(self, models, case, layout, depth):
        cfg, params = models[case]
        prompts = _prompts(cfg)
        kw = ({"cache_layout": "paged", "page_size": 8}
              if layout == "paged" else {})
        base, _ = _serve(cfg, params, prompts, spec_depth=depth,
                         draft="ngram", **kw)
        got, eng = _serve(cfg, params, prompts, spec_depth=depth,
                          draft="ngram", backend="pallas", **kw)
        assert got == base
        m = eng.metrics()
        assert m["verify_backend"] == "pallas"
        assert m["backend"] == "pallas"

    def test_layer_draft_stream_matches_einsum(self, models):
        """The layer-fraction draft drives extra pallas decode_steps on
        its own ring; the composed round must stay einsum-identical."""
        cfg, params = models["latent"]
        prompts = _prompts(cfg)
        base, _ = _serve(cfg, params, prompts, spec_depth=2,
                         draft="layers:2")
        got, _ = _serve(cfg, params, prompts, spec_depth=2,
                        draft="layers:2", backend="pallas")
        assert got == base


class TestMeshStreamParity:
    """The shard_map kernel path on a (2, 4) forced-host mesh must emit
    the single-device einsum streams."""

    @pytest.mark.parametrize("case", list(CASES))
    def test_ring_stream_matches(self, models, mesh24, case):
        cfg, params = models[case]
        prompts = _prompts(cfg)
        base, _ = _serve(cfg, params, prompts, spec_depth=2, draft="ngram")
        got, eng = _serve(cfg, params, prompts, spec_depth=2, draft="ngram",
                          backend="pallas", mesh=mesh24)
        assert got == base
        assert eng.metrics()["decode_kernel_sharded"] is True

    def test_paged_stream_matches(self, models, mesh24):
        cfg, params = models["latent"]
        prompts = _prompts(cfg)
        base, _ = _serve(cfg, params, prompts, spec_depth=2, draft="ngram")
        got, eng = _serve(cfg, params, prompts, spec_depth=2, draft="ngram",
                          backend="pallas", mesh=mesh24,
                          cache_layout="paged", page_size=8)
        assert got == base
        assert eng.metrics()["decode_kernel_sharded"] is True

    def test_non_divisible_ring_falls_back_unsharded(self, models, mesh24):
        """max_len=42 does not divide over 4 "model" shards: the kernels
        must drop to the unsharded path (decode_kernel_sharded False)
        with identical streams — divisibility is a routing detail, not a
        correctness cliff."""
        cfg, params = models["latent"]
        prompts = _prompts(cfg)
        base, _ = _serve(cfg, params, prompts, max_len=42, spec_depth=2,
                         draft="ngram")
        got, eng = _serve(cfg, params, prompts, max_len=42, spec_depth=2,
                          draft="ngram", backend="pallas", mesh=mesh24)
        assert got == base
        assert eng.metrics()["decode_kernel_sharded"] is False


class TestEngineEdges:
    def test_eos_mid_round_pallas(self, models):
        """An EOS accepted mid-round on the kernel verify path stops the
        stream at exactly the sequential point."""
        cfg, params = models["latent"]
        g = np.random.default_rng(12)
        pr = g.integers(0, cfg.vocab_size, 6).astype(np.int32)
        full, _ = _serve(cfg, params, [pr], max_new=10)
        eos = int(full[0][3])            # 4th emitted token becomes EOS

        def serve(**kw):
            eng = Engine(cfg, params, max_slots=2, max_len=40, **kw)
            eng.submit(Request(uid=0, prompt=pr.copy(), max_new_tokens=10,
                               eos_id=eos))
            return eng.run()[0].out_tokens

        ref = serve()
        assert ref[-1] == eos or len(ref) == 10
        assert serve(backend="pallas", spec_depth=3, draft="ngram") == ref
        assert serve(backend="pallas", spec_depth=2,
                     draft="layers:2") == ref

    def test_aot_spec_kernel_no_retrace(self, models):
        """AOT + spec_depth=2 on the kernel path compiles the spec window
        exactly once; serving must not trace anything new."""
        cfg, params = models["latent"]
        prompts = _prompts(cfg)
        base, _ = _serve(cfg, params, prompts, spec_depth=2, draft="ngram")
        eng = Engine(cfg, params, max_slots=4, max_len=40, spec_depth=2,
                     draft="ngram", backend="pallas", aot=True)
        compiled = dict(eng.trace_counts)
        assert compiled["window"] == 1
        for i, pr in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=6))
        eng.run()
        assert {r.uid: r.out_tokens for r in eng.finished} == base
        assert eng.trace_counts == compiled, "spec serving retraced"

    def test_fallback_warns_once(self):
        """backend="pallas" on an arch whose attention has no kernel
        (absorbed MLA) must warn loudly instead of silently running
        einsum — and metrics still reports the effective verify path."""
        cfg = dataclasses.replace(get_config("deepseek-v3-671b", smoke=True),
                                  dtype=jnp.float32)
        params = T.init_params(cfg, KEY)
        with pytest.warns(RuntimeWarning, match="fall back to einsum"):
            eng = Engine(cfg, params, max_slots=2, max_len=40,
                         backend="pallas", spec_depth=2, draft="ngram")
        m = eng.metrics()
        assert m["verify_backend"] == "einsum"
        assert m["decode_kernel_sharded"] is False
